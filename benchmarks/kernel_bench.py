"""ConvDK kernel micro-benchmarks (CPU interpret-mode wall times; correctness
+ harness shape — real perf is measured via the dry-run roofline on TPU).

Emits ``name,us_per_call,derived`` CSV rows like benchmarks/run.py expects.

``--fused`` additionally prints the fused-vs-staged traffic comparison for
BOTH fused block families (autotuned schedules):

* every MobileNet-V2 separable block plus the EfficientNet-V2-style k=7
  stem rows (single-pass fused kernel), and
* every EfficientNet-B0 MBConv block (two-pass SE-aware fused kernel,
  per-layer retain/recompute choice),

plus interpret-mode wall times on one block of each.  Every reported
number is labeled with the **residency** (input-staging mode, see
``kernels.staging``) it was modeled/measured under — ``--residency``
selects the mode(s): ``auto`` (default; the autotuner solves residency per
layer and the report shows its pick), one of ``resident`` / ``strip_dma``
/ ``strip_dma_db``, or a comma list for a per-mode report.  Exits nonzero
if any layer's fused traffic is not strictly below the staged baseline
under any requested mode — the CI gate for the tentpole claim.
"""

from __future__ import annotations

import argparse
import sys

import jax.numpy as jnp
import numpy as np

from repro.compat import pallas_dma_priority_supported
from repro.core import telemetry
from repro.core.autotune import (
    BlockRow,
    benchmark_mbconv_sweep,
    get_fused_schedule,
    get_fusedmb_schedule,
    get_mbconv_schedule,
    network_rows_from_table,
)
from repro.core.perfmodel import (
    COLLECTIVE_MODES,
    RESIDENCY_MODES,
    MBConvShape,
    can_psum_scatter,
)
from repro.core.telemetry import measure
from repro.core.trajectory import write_bench
from repro.core.workloads import (
    EFFICIENTNET_B0_MBCONV,
    EFFICIENTNET_V2_K7_SEPARABLE,
    MOBILENET_V2_SEPARABLE,
    effnet_v2_chain_rows,
    mobilenet_v3_chain_rows,
)

# the three end-to-end workloads --family selects from: the all-MBConv
# EfficientNet-B0 chain (the original gate), MobileNet-V3-Large (per-row
# act/SE variants) and EfficientNet-V2-S (mixed Fused-MBConv + MBConv)
FAMILY_CHOICES = ("b0", "v3l", "v2s")


def family_chain(family: str) -> tuple:
    """The family-generic ``BlockRow`` chain of one ``--family`` choice."""
    if family == "b0":
        return tuple(BlockRow(*r)
                     for r in network_rows_from_table(EFFICIENTNET_B0_MBCONV))
    if family == "v3l":
        return mobilenet_v3_chain_rows("large")
    return effnet_v2_chain_rows()
from repro.kernels import (
    DEFAULT_RESIDENCY, causal_conv1d_ref, convdk_causal_conv1d,
    convdk_depthwise2d, convdk_fused_separable, convdk_mbconv_fused,
    convdk_mbconv_staged, convdk_separable_staged, depthwise2d_ref,
    mbconv_ref, separable_ref,
)


def _time(fn, *args, iters=5):
    """Mean microseconds per call via the shared ``telemetry.measure``
    harness (one warmup call, ``iters`` timed calls — the old local loop
    evaluated ``fn`` twice during warmup to probe its return type)."""
    return measure(fn, *args, iters=iters).mean_us


def rows():
    rng = np.random.default_rng(0)
    out = []

    # depthwise 2D: a MobileNet-ish layer
    x = jnp.asarray(rng.normal(size=(1, 28, 28, 128)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(3, 3, 128)), jnp.float32)
    us_k = _time(lambda: convdk_depthwise2d(x, w, interpret=True))
    us_r = _time(lambda: depthwise2d_ref(x, w))
    err = float(jnp.abs(convdk_depthwise2d(x, w, interpret=True)
                        - depthwise2d_ref(x, w)).max())
    out.append(("convdk_dw2d_28x28x128_interp", us_k, f"maxerr={err:.1e}"))
    out.append(("lax_dw2d_28x28x128_ref", us_r, ""))

    # fused separable block: same layer + 1x1 projection to 64 channels.
    # The fused kernel runs its default staging mode — labeled, so the
    # wall time is never misattributed to a residency it did not run.
    wp = jnp.asarray(rng.normal(size=(128, 64)), jnp.float32)
    us_f = _time(lambda: convdk_fused_separable(x, w, wp, interpret=True))
    us_s = _time(lambda: convdk_separable_staged(x, w, wp, interpret=True))
    us_x = _time(lambda: separable_ref(x, w, wp))
    err = float(jnp.abs(convdk_fused_separable(x, w, wp, interpret=True)
                        - separable_ref(x, w, wp)).max())
    out.append(("convdk_fused_sep_28x28x128to64_interp", us_f,
                f"maxerr={err:.1e} res={DEFAULT_RESIDENCY}"))
    out.append(("convdk_staged_sep_28x28x128to64_interp", us_s, ""))
    out.append(("xla_sep_28x28x128to64_ref", us_x, ""))

    # causal conv1d: the Mamba-2 stem shape (per-device slice)
    xs = jnp.asarray(rng.normal(size=(2, 1024, 256)), jnp.float32)
    ws = jnp.asarray(rng.normal(size=(4, 256)), jnp.float32)
    us_k = _time(lambda: convdk_causal_conv1d(xs, ws, interpret=True))
    us_r = _time(lambda: causal_conv1d_ref(xs, ws))
    err = float(jnp.abs(convdk_causal_conv1d(xs, ws, interpret=True)
                        - causal_conv1d_ref(xs, ws)).max())
    out.append(("convdk_conv1d_1024x256_interp", us_k, f"maxerr={err:.1e}"))
    out.append(("lax_conv1d_1024x256_ref", us_r, ""))
    return out


def _is_fallback(effective, requested) -> bool:
    """True when a sharded request silently priced single-device (the
    all-or-nothing kernel routing: a mesh axis did not divide)."""
    return requested != (1, 1) and effective != requested


def _mesh_label(effective, fallback: bool) -> str:
    label = f"{effective[0]}x{effective[1]}"
    return f"{label} (fallback)" if fallback else label


def fused_traffic_report(mesh_shape=(1, 1), residency=None) -> bool:
    """Modeled HBM traffic, fused vs staged, every MobileNet-V2 separable
    block plus the k=7 EfficientNet-V2 stem rows (f32).  Returns True iff
    fused < staged for ALL layers.

    ``residency=None`` lets the autotuner solve the staging mode per layer
    (the pick is the ``residency`` column); a pinned mode prices every
    layer under that mode.  With a non-trivial ``mesh_shape`` the
    comparison is the SHARDED one (batch 8 over "data", c_out over
    "model"): per-device fused bytes vs the staged pipeline partitioned
    identically, totals summed over the mesh (the separable sharding is
    collective-free)."""
    b = 8 if mesh_shape != (1, 1) else 1
    print(f"# mesh={mesh_shape[0]}x{mesh_shape[1]} batch={b} "
          f"residency={residency or 'auto'}")
    print("layer,c_in,hw,k,s,c_out,tile_h,residency,mesh,per_dev_bytes,"
          "dma_issues,fused_bytes,staged_bytes,saving_pct")
    ok = True
    fallbacks = 0
    table = ([(f"mbv2_dw{i}", layer, c_out)
              for i, (layer, c_out) in enumerate(MOBILENET_V2_SEPARABLE)]
             + [(f"effv2_k7_dw{i}", layer, c_out)
                for i, (layer, c_out) in enumerate(
                    EFFICIENTNET_V2_K7_SEPARABLE)])
    for name, layer, c_out in table:
        sch = get_fused_schedule(b, layer.h, layer.w, layer.c, c_out,
                                 layer.k, layer.s, mesh_shape=mesh_shape,
                                 residency=residency)
        f, s = sch.total_bytes, sch.staged_total_bytes
        # a grid the mesh axes do not divide prices (and runs) on ONE
        # device: label it explicitly and keep it OUT of the sharded
        # gate — the gate must never pass on mislabeled numbers (such
        # rows are gated by the single-device run instead)
        fallback = _is_fallback(sch.mesh_shape, mesh_shape)
        if fallback:
            fallbacks += 1
        else:
            ok &= f < s
        print(f"{name},{layer.c},{layer.h},{layer.k},{layer.s},{c_out},"
              f"{sch.tile_h},{sch.residency},"
              f"{_mesh_label(sch.mesh_shape, fallback)},"
              f"{sch.traffic.total_bytes},"
              f"{sch.traffic.dma_issues},{f},{s},"
              f"{100 * sch.modeled_saving:.1f}")
    if fallbacks:
        print(f"# {fallbacks} fallback row(s) excluded from the gate")
        if fallbacks == len(table):
            # a mesh that divides NOTHING must not turn the gate green
            # vacuously (e.g. a typo'd --mesh in CI)
            print("# every row fell back: nothing was gated -> FAIL")
            ok = False
    print(f"# fused strictly below staged on all sharded layers "
          f"[residency={residency or 'auto'}]: {ok}")
    return ok


def mbconv_traffic_report(mesh_shape=(1, 1), residency=None,
                          collective=None, family="b0", chain=None):
    """Modeled HBM traffic of the fused block pipelines vs their staged
    baselines for every block of one ``--family`` chain (f32), with the
    autotuned schedule — ``residency``/``collective`` pin their axes when
    given.  Family-generic: ``mbconv`` rows price the two-pass SE-aware
    pipeline (per-row act and SE — a no-SE row pays zero SE bytes and,
    under a mesh, zero squeeze-collective bytes), ``fusedmb`` rows the
    single-pass pipeline (no mode axis — the column prints ``-``; the
    only collective is the projection reduction).  Returns (ok, totals):
    ok iff fused traffic is strictly below staged for ALL sharded layers
    (fallback rows labeled and excluded), totals mapping layer name ->
    mesh-wide fused bytes (None for fallback rows).

    With a non-trivial ``mesh_shape`` the comparison is the SHARDED one
    (batch 8 over "data", c_mid over "model"): per-device fused bytes plus
    the collective bytes — surfaced in their own ``collective_bytes``
    column — vs the staged pipeline partitioned identically (which pays
    the SAME collectives)."""
    chain = family_chain(family) if chain is None else chain
    b = 8 if mesh_shape != (1, 1) else 1
    print(f"# family={family} mesh={mesh_shape[0]}x{mesh_shape[1]} "
          f"batch={b} residency={residency or 'auto'} "
          f"collective={collective or 'auto'}")
    print("layer,c_in,c_mid,c_out,hw,k,s,act,se,tile_h,mode,residency,"
          "collective,mesh,per_dev_bytes,dma_issues,collective_bytes,"
          "fused_bytes,staged_bytes,saving_pct")
    ok = True
    fallbacks = 0
    dropped = 0
    totals = {}
    for i, r in enumerate(chain):
        name = f"{family}_{r.family}{i}"
        # a pinned psum_scatter may not be runnable on a layer (c_out
        # does not divide the model axis): price the ring instead, label
        # the row, keep it out of the pinned gate — same policy as the
        # mesh-fallback rows.  The model's own pre-check keeps every
        # other ValueError (solver/cache regressions) loud.
        pin_dropped = (collective == "psum_scatter"
                       and mesh_shape[1] > 1
                       and not can_psum_scatter(
                           MBConvShape(b=b, h=r.h, w=r.w, c_in=r.c_in,
                                       c_mid=r.c_mid, c_out=r.c_out,
                                       k=r.k, s=r.s),
                           mesh_shape))
        eff_coll = "ring_allreduce" if pin_dropped else collective
        if r.family == "fusedmb":
            sch = get_fusedmb_schedule(
                b, r.h, r.w, r.c_in, r.c_mid, r.c_out, r.k, r.s,
                mesh_shape=mesh_shape, residency=residency,
                collective=eff_coll, act=r.act)
        else:
            sch = get_mbconv_schedule(
                b, r.h, r.w, r.c_in, r.c_mid, r.c_out, r.k, r.s,
                se_ratio=r.se_ratio, mesh_shape=mesh_shape,
                residency=residency, collective=eff_coll, act=r.act)
        f, st = sch.total_bytes, sch.staged_total_bytes
        fallback = _is_fallback(sch.mesh_shape, mesh_shape)
        if fallback or pin_dropped:
            fallbacks += fallback
            dropped += pin_dropped and not fallback
            totals[name] = None
        else:
            ok &= f < st
            totals[name] = f
        coll_label = sch.collective + (" (pin dropped)" if pin_dropped
                                       else "")
        se_label = "on" if r.family == "mbconv" and r.se_ratio > 0 \
            else "off"
        print(f"{name},{r.c_in},{r.c_mid},{r.c_out},{r.h},{r.k},{r.s},"
              f"{r.act},{se_label},"
              f"{sch.tile_h},{getattr(sch, 'mode', '-')},{sch.residency},"
              f"{coll_label},"
              f"{_mesh_label(sch.mesh_shape, fallback)},"
              f"{sch.traffic.total_bytes},{sch.traffic.dma_issues},"
              f"{sch.collective_bytes},{f},{st},"
              f"{100 * sch.modeled_saving:.1f}")
    if dropped:
        print(f"# {dropped} row(s) could not run the pinned collective "
              f"(c_out does not divide the model axis): priced as "
              f"ring_allreduce, excluded from the gate")
    if fallbacks:
        print(f"# {fallbacks} fallback row(s) excluded from the gate")
        if fallbacks == len(chain):
            # a mesh that divides NOTHING must not turn the gate green
            # vacuously (e.g. a typo'd --mesh in CI)
            print("# every row fell back: nothing was gated -> FAIL")
            ok = False
    print(f"# fused strictly below staged on all sharded layers "
          f"[family={family}, residency={residency or 'auto'}, "
          f"collective={collective or 'auto'}]: {ok}")
    return ok, totals


def mbconv_collective_sweep(mesh_shape, residency=None, family="b0",
                            chain=None) -> bool:
    """The model-sharded collective gate: price every block of the chain
    under BOTH collective modes — the autotuned pick (scatter where it is
    runnable and wins) and the ring pin — and require the autotuned total
    <= the ring-pinned total on every sharded layer.  Returns True iff
    both fused-vs-staged gates AND the autotuned-vs-ring comparison
    hold."""
    auto_ok, auto_totals = mbconv_traffic_report(mesh_shape, residency,
                                                 None, family, chain)
    print()
    ring_ok, ring_totals = mbconv_traffic_report(mesh_shape, residency,
                                                 "ring_allreduce", family,
                                                 chain)
    worse = [name for name, t in auto_totals.items()
             if t is not None and ring_totals.get(name) is not None
             and t > ring_totals[name]]
    print(f"# autotuned collective <= ring-pinned on all sharded layers: "
          f"{not worse}" + (f" (worse: {','.join(worse)})" if worse else ""))
    return auto_ok and ring_ok and not worse


def network_report(mesh_shape, family="b0", chain=None) -> bool:
    """The network-level layout gate: solve the whole chain (stem +
    blocks + head boundary) with the layout DP and compare its end-to-end
    modeled bytes against the greedy per-layer reference (every block
    solved in isolation, every sharded exit repaying its all-gather at
    the next replicated entry).  The layout-transition bytes are their
    own column — greedy's repays are exactly where the per-layer scatter
    win evaporates.

    Gate: solved <= greedy always.  On a model-sharded mesh, when the
    chain carries an identity-expand MBConv row (the one place a sharded
    boundary strictly wins — B0's block 0, V3-Large's block 0) the gate
    additionally requires solved STRICTLY below greedy with at least one
    adjacent pair staying sharded.  A chain with no such row
    (EfficientNet-V2-S: fusedmb entries are always replicated, its
    MBConv tail is all real-expand) legitimately ties greedy — the gate
    then instead requires every fusedmb block to enter replicated (the
    family's layout contract, priced AND executed that way)."""
    from repro.core.autotune import (
        greedy_network_schedule, solve_network_schedule,
    )
    chain = family_chain(family) if chain is None else chain
    b = 8 if mesh_shape != (1, 1) else 1
    solved = solve_network_schedule(chain, b, mesh_shape)
    greedy = greedy_network_schedule(chain, b, mesh_shape)
    mb = 1e6
    print(f"# network-level layout solve [{family}]: mesh={mesh_shape[0]}x"
          f"{mesh_shape[1]} batch={b} chain=stem+{len(chain)} blocks")
    print("element,family,c_in,c_mid,c_out,hw,in_layout,out_layout,mode,"
          "residency,collective,block_mb,transition_mb")
    for plan, tag in ((solved, "solved"), (greedy, "greedy")):
        print(f"# {tag} plan")
        r0 = chain[0]
        print(f"stem[{tag}],,3,,{r0.c_in},{r0.h},,{plan.stem_layout},,,,"
              f"{plan.stem_bytes / mb:.3f},0.000")
        for p in plan.blocks:
            sh = p.shape
            trans = p.boundary_bytes + p.schedule.transition_bytes
            print(f"{family}_{p.family}{p.index}[{tag}],{p.family},"
                  f"{sh.c_in},{sh.c_mid},"
                  f"{sh.c_out},{sh.h},{p.in_layout},{p.out_layout},"
                  f"{getattr(p.schedule, 'mode', '-')},"
                  f"{p.schedule.residency},"
                  f"{p.schedule.collective},"
                  f"{p.schedule.total_bytes / mb:.3f},{trans / mb:.3f}")
        print(f"head[{tag}],,,,,,,,,,,0.000,"
              f"{plan.head_boundary_words * plan.dtype_bytes / mb:.3f}")
        print(f"# {tag} totals: stem={plan.stem_bytes / mb:.3f} MB, "
              f"blocks={plan.block_bytes / mb:.3f} MB, "
              f"transitions={plan.transition_bytes / mb:.3f} MB, "
              f"end-to-end={plan.total_bytes / mb:.3f} MB")
    pairs = solved.sharded_pairs
    pair_label = ",".join(
        f"{'stem' if a < 0 else f'block{a}'}->block{b_}" for a, b_ in pairs)
    print(f"# sharded boundary pairs (solved): "
          f"{pair_label or 'none'}")
    ok = solved.total_bytes <= greedy.total_bytes
    has_identity = any(r.family == "mbconv" and r.c_mid == r.c_in
                       for r in chain)
    if mesh_shape[1] > 1 and has_identity:
        ok &= solved.total_bytes < greedy.total_bytes and len(pairs) >= 1
        print(f"# solved strictly below greedy with >=1 sharded pair: "
              f"{ok} ({solved.total_bytes / mb:.3f} vs "
              f"{greedy.total_bytes / mb:.3f} MB)")
    elif mesh_shape[1] > 1:
        bad_entries = [p.index for p in solved.blocks
                       if p.family == "fusedmb"
                       and p.in_layout != "replicated"]
        ok &= not bad_entries
        print(f"# no identity-expand row: solved <= greedy and every "
              f"fusedmb entry replicated: {ok}"
              + (f" (sharded fusedmb entries: {bad_entries})"
                 if bad_entries else ""))
    else:
        print(f"# solved <= greedy (degenerate mesh): {ok}")
    return ok


def pipeline_report(mesh_shape, records=None, family="b0",
                    chain=None) -> bool:
    """The cross-block pipelining gate: solve the chain (layout DP +
    overlap annotation), print the per-boundary serialized-vs-pipelined
    modeled latency table, and compare the chain totals.

    Latencies come from ``PerfCoefficients`` — the repo-default fit
    unless ``records`` (a ``measure_b0`` run from this invocation) is
    handed in, in which case a FRESH fit over its candidate points is
    installed first and reported alongside (the ``--measure`` point:
    same verdict, this host's stopwatch).

    Gate: on a model-sharded mesh the plan must pipeline >= 1 boundary
    AND its modeled chain latency must sit STRICTLY below the fully
    serialized chain; on a degenerate mesh pipelined <= serialized (the
    annotation may legitimately find nothing to overlap).  On chains
    with Fused-MBConv blocks, every boundary BEHIND a one-pass producer
    must additionally be serial — a single-pass block has no pass 2 to
    hide a consumer's DMA behind, and the report must price that
    honestly rather than claim phantom overlap."""
    from repro.core.autotune import solve_network_schedule
    from repro.core.perfmodel import (
        fit_perf_coefficients, get_perf_coefficients, set_perf_coefficients,
    )
    chain = family_chain(family) if chain is None else chain
    b = 8 if mesh_shape != (1, 1) else 1
    fitted = None
    if records:
        samples = [
            {"walltime_us": c["walltime_us"],
             "modeled_bytes": c["modeled_bytes"],
             "dma_issues": c.get("modeled_dma_issues", 0),
             "collective_bytes": rec.get("collective_bytes", 0)}
            for rec in records for c in rec.get("candidates", [])]
        fitted = fit_perf_coefficients(samples)
        set_perf_coefficients(fitted)
        print(f"# --measure point: coefficients refit from "
              f"{fitted.n_samples} candidate timings on this host "
              f"(us_per_mb={fitted.us_per_mb:.2f}, "
              f"us_per_dma_issue={fitted.us_per_dma_issue:.2f}, "
              f"rms={fitted.rms_us:.1f}us)")
    coeffs = get_perf_coefficients()
    try:
        plan = solve_network_schedule(chain, b, mesh_shape)
        print(f"# cross-block pipelining [{family}]: mesh={mesh_shape[0]}x"
              f"{mesh_shape[1]} batch={b} "
              f"coeffs={'measured-refit' if fitted else 'repo-default'}")
        print("boundary,pass2_us,pass1_us,serialized_us,overlap_us,overlap")
        for row in plan.boundary_latencies(coeffs):
            a, b_ = row["boundary"]
            print(f"block{a}->block{b_},{row['pass2_us']:.1f},"
                  f"{row['pass1_us']:.1f},{row['serialized_us']:.1f},"
                  f"{row['overlap_us']:.1f},{row['overlap']}")
        serial = plan.serial_latency_us(coeffs)
        pipe = plan.pipelined_latency_us(coeffs)
        n_pipe = len(plan.pipelined_boundaries)
        print(f"# chain totals: serialized={serial:.1f}us "
              f"pipelined={pipe:.1f}us "
              f"({n_pipe}/{max(0, len(plan.blocks) - 1)} boundaries "
              f"pipelined, saving {serial - pipe:.1f}us)")
        if mesh_shape[1] > 1:
            ok = n_pipe >= 1 and pipe < serial
            print(f"# >=1 pipelined boundary and pipelined strictly below "
                  f"serialized: {ok}")
        else:
            ok = pipe <= serial
            print(f"# pipelined <= serialized (degenerate mesh): {ok}")
        behind_one_pass = {p.index + 1 for p in plan.blocks[:-1]
                          if p.family == "fusedmb"}
        if behind_one_pass:
            phantom = sorted(behind_one_pass
                             & set(plan.pipelined_boundaries))
            ok &= not phantom
            print(f"# every boundary behind a one-pass producer serial: "
                  f"{not phantom}"
                  + (f" (phantom overlap into blocks {phantom})"
                     if phantom else ""))
        return ok
    finally:
        if fitted is not None:
            set_perf_coefficients(None)


def mbconv_walltime_row():
    """Interpret-mode wall times + numerics check on one small MBConv block
    (fused two-pass vs staged vs the pure-lax reference).  Fused rows are
    labeled with the residency they executed under."""
    rng = np.random.default_rng(1)
    ci, e, co, k = 16, 4, 24, 3
    cm, cse = ci * e, max(1, ci // 4)
    r = lambda *s: jnp.asarray(rng.normal(size=s), jnp.float32)  # noqa: E731
    args = (r(1, 28, 28, ci), r(ci, cm), r(k, k, cm) * 0.3,
            r(cm, cse), r(cse) * 0.1, r(cse, cm), r(cm) * 0.1, r(cm, co))
    us_f = _time(lambda: convdk_mbconv_fused(*args, stride=2, mode="retain",
                                             interpret=True))
    us_r = _time(lambda: convdk_mbconv_fused(*args, stride=2,
                                             mode="recompute",
                                             interpret=True))
    us_s = _time(lambda: convdk_mbconv_staged(*args, stride=2,
                                              interpret=True))
    us_x = _time(lambda: mbconv_ref(*args, stride=2))
    err = float(jnp.abs(
        convdk_mbconv_fused(*args, stride=2, mode="retain", interpret=True)
        - mbconv_ref(*args, stride=2)).max())
    return [
        ("convdk_mbconv_retain_28x28x16e4to24_interp", us_f,
         f"maxerr={err:.1e} res={DEFAULT_RESIDENCY}"),
        ("convdk_mbconv_recompute_28x28x16e4to24_interp", us_r,
         f"res={DEFAULT_RESIDENCY}"),
        ("convdk_mbconv_staged_28x28x16e4to24_interp", us_s, ""),
        ("xla_mbconv_28x28x16e4to24_ref", us_x, ""),
    ]


def _measured_b0_shapes(scale):
    """B0 rows at the measured (CPU-interpret-affordable) resolution:
    spatial dims divided by ``scale`` (floored at the kernel size), batch
    1.  Byte records pair modeled bytes with walltime AT THIS SHAPE — an
    honest pairing; the full-resolution model tables are gated separately
    by ``--fused``."""
    for i, (ci, co, e, k, s, hw) in enumerate(EFFICIENTNET_B0_MBCONV):
        yield f"b0_mbconv{i}", ci, ci * e, co, k, s, max(k, hw // scale), hw


def _mbconv_args(rng, ci, cm, co, k, hw):
    r = lambda *s: jnp.asarray(rng.normal(size=s), jnp.float32)  # noqa: E731
    cse = max(1, ci // 4)
    return (r(1, hw, hw, ci), r(ci, cm), r(k, k, cm) * 0.3,
            r(cm, cse), r(cse) * 0.1, r(cse, cm), r(cm) * 0.1, r(cm, co))


def measure_b0(scale=4, iters=3, persist=True, bench_out=None):
    """The measured ground-truth loop: time the real fused MBConv kernel
    per (B0 layer x schedule-axes) point and emit the ``BENCH_<host>.json``
    trajectory artifact.

    Per layer: the candidate set is ``benchmark_mbconv_sweep``'s default —
    the solver's own pick under each pinned pass-2 mode, i.e. exactly the
    points the retain/recompute crossover model claims to order.  The
    record's gated fields (modeled bytes, solver axes) are deterministic;
    ``walltime_us`` (the solver's point) gates only against a same-host
    baseline, and the stopwatch's winner is recorded separately as
    ``measured_best`` (informational — timing noise must not flip gated
    fields).  With ``persist`` the winner also lands in the schedule
    cache's measured tier, keyed at the measured shape.
    """
    from repro.core.perfmodel import MBConvShape as _MBShape
    from repro.core.perfmodel import mbconv_fused_traffic, mbconv_pass_traffic

    rng = np.random.default_rng(7)
    records = []
    for name, ci, cm, co, k, s, hw, full_hw in _measured_b0_shapes(scale):
        sch = get_mbconv_schedule(1, hw, hw, ci, cm, co, k, s)
        args = _mbconv_args(rng, ci, cm, co, k, hw)
        best, results = benchmark_mbconv_sweep(
            *args, stride=s, iters=iters, interpret=True, persist=persist)
        shape = _MBShape(b=1, h=hw, w=hw, c_in=ci, c_mid=cm, c_out=co,
                         k=k, s=s)
        cands = []
        for res in results:
            t = mbconv_fused_traffic(shape, res["tile_h"], res["mode"],
                                     residency=res["residency"])
            cands.append({
                "axes": {"tile_h": res["tile_h"], "mode": res["mode"],
                         "residency": res["residency"]},
                "walltime_us": res["seconds"] * 1e6,
                "modeled_bytes": t.total_bytes,
                "modeled_dma_issues": t.dma_issues,
            })
        solver_point = {"tile_h": sch.tile_h, "mode": sch.mode,
                        "residency": sch.residency}
        at_solver = next(
            (c for c in cands if c["axes"] == solver_point), None)
        if at_solver is None:
            m = measure(
                lambda: convdk_mbconv_fused(
                    *args, stride=s, tile_h=sch.tile_h, mode=sch.mode,
                    residency=sch.residency, interpret=True), iters=iters)
            at_solver = {"axes": solver_point, "walltime_us": m.best_us,
                         "modeled_bytes": sch.traffic.total_bytes,
                         "modeled_dma_issues": sch.traffic.dma_issues}
            cands.append(at_solver)
        # the pass split of the SOLVER's point: the two-pass pipelining
        # model prices boundary overlap from exactly these two halves
        # (they sum to modeled_bytes by construction — gated)
        p1, p2 = mbconv_pass_traffic(shape, sch.tile_h, sch.mode,
                                     residency=sch.residency)
        records.append({
            "name": name,
            "shape": {"b": 1, "hw": hw, "full_hw": full_hw, "c_in": ci,
                      "c_mid": cm, "c_out": co, "k": k, "s": s},
            "axes": solver_point,
            "modeled_bytes": at_solver["modeled_bytes"],
            "modeled_pass1_bytes": p1.total_bytes,
            "modeled_pass2_bytes": p2.total_bytes,
            "modeled_dma_issues": at_solver["modeled_dma_issues"],
            "collective_bytes": 0,
            "walltime_us": at_solver["walltime_us"],
            "candidates": cands,
            "measured_best": {"tile_h": best["tile_h"],
                              "mode": best["mode"],
                              "residency": best["residency"],
                              "walltime_us": best["seconds"] * 1e6},
        })
        agree = ("agree" if best["mode"] == sch.mode else "DISAGREE")
        print(f"{name},{hw},{sch.tile_h},{sch.mode},{sch.residency},"
              f"{at_solver['walltime_us']:.1f}us,"
              f"measured_best={best['mode']}@{best['seconds'] * 1e6:.1f}us,"
              f"{agree}")
    config = {"scale": scale, "iters": iters, "mesh": "1x1", "batch": 1,
              "dtype_bytes": 4, "interpret": True}
    knobs = {
        "prefetch_priority_supported": pallas_dma_priority_supported(),
        "prefetch_priority": ("unsupported by installed pallas — not "
                              "exercised" if not
                              pallas_dma_priority_supported() else 1),
        "k_w_strip_split": "not implemented; verdict from roofline fit",
    }
    if bench_out is not None:
        path = write_bench(bench_out, records, config=config,
                           counters=telemetry.snapshot(), knobs=knobs)
        print(f"# BENCH artifact: {path}")
    disagreements = sum(
        1 for r in records
        if r["measured_best"]["mode"] != r["axes"]["mode"])
    print(f"# measured {len(records)} layers; stopwatch disagrees with the "
          f"solver's mode on {disagreements}")
    return records


def _parse_mesh(text):
    try:
        dp, mp = (int(t) for t in text.lower().split("x"))
    except ValueError:
        raise SystemExit(f"--mesh wants DxM (e.g. 2x4), got {text!r}")
    if dp < 1 or mp < 1:
        raise SystemExit(f"--mesh axes must be >= 1, got {text!r}")
    return dp, mp


def _parse_residencies(text):
    """'auto' | mode | comma list -> list of residency requests (None =
    solver's choice)."""
    reqs = []
    for token in text.lower().split(","):
        token = token.strip()
        if token == "auto":
            reqs.append(None)
        elif token in RESIDENCY_MODES:
            reqs.append(token)
        else:
            raise SystemExit(
                f"--residency wants auto or one of {RESIDENCY_MODES} "
                f"(comma list ok), got {token!r}")
    return reqs


def _parse_collective(text):
    """'auto' -> None (the solver picks; under a model-sharded mesh the
    report then also runs the ring-pinned sweep), else a pinned mode."""
    token = text.lower().strip()
    if token == "auto":
        return None
    if token in COLLECTIVE_MODES:
        return token
    raise SystemExit(
        f"--collective wants auto or one of {COLLECTIVE_MODES}, "
        f"got {token!r}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fused", action="store_true",
                    help="print the fused-vs-staged HBM traffic comparison "
                         "for every MobileNet-V2 separable block (+ k=7 "
                         "stem rows) AND every EfficientNet-B0 MBConv "
                         "block (exit 1 if the fused pipeline loses any "
                         "layer under any requested residency)")
    ap.add_argument("--family", default="b0", metavar="FAM[,FAM...]",
                    help="with --fused: the end-to-end workload chain(s) "
                         "to gate — b0 (EfficientNet-B0, default), v3l "
                         "(MobileNet-V3-Large: per-block act/SE variants), "
                         "v2s (EfficientNet-V2-S: Fused-MBConv head + "
                         "MBConv tail), or a comma list")
    ap.add_argument("--mesh", default=None, metavar="DxM",
                    help="with --fused: price the SHARDED pipelines over a "
                         "(data, model) mesh of this shape — per-device "
                         "traffic + psum bytes vs the identically "
                         "partitioned staged baseline (e.g. --mesh 2x4)")
    ap.add_argument("--residency", default="auto", metavar="MODE[,MODE...]",
                    help="with --fused: input-staging mode(s) to price the "
                         "fused pipelines under — auto (default: the "
                         "autotuner solves per layer), resident, strip_dma, "
                         "strip_dma_db, or a comma list for per-mode "
                         "reports")
    ap.add_argument("--collective", default="auto", metavar="MODE",
                    help="with --fused --mesh: MBConv projection-reduction "
                         "layout — auto (default: the autotuner solves per "
                         "layer AND the gate re-runs ring-pinned, requiring "
                         "the autotuned total <= the ring total), "
                         "ring_allreduce, or psum_scatter")
    ap.add_argument("--network", action="store_true",
                    help="with --fused: run the network-level layout DP "
                         "over the whole B0 chain and gate its end-to-end "
                         "modeled bytes against greedy per-layer picks "
                         "(strictly lower, with >=1 boundary staying "
                         "sharded, on a model-sharded mesh)")
    ap.add_argument("--pipeline", action="store_true",
                    help="with --network: run the cross-block pipelining "
                         "report — per-boundary serialized-vs-pipelined "
                         "modeled latency over the solved B0 plan, gated "
                         "(>=1 pipelined boundary, pipelined strictly "
                         "below serialized) on a model-sharded mesh; with "
                         "--measure the coefficients are refit from this "
                         "run's stopwatch first")
    ap.add_argument("--measure", action="store_true",
                    help="time REAL fused-MBConv executions per (B0 layer "
                         "x schedule-axes) point at a scaled-down "
                         "resolution, persist stopwatch winners into the "
                         "schedule cache's measured tier, and emit the "
                         "BENCH_<host>.json trajectory artifact")
    ap.add_argument("--bench-out", default=None, metavar="DIR",
                    help="with --measure: directory (or explicit .json "
                         "path) for the BENCH_<host>.json artifact "
                         "(default: no artifact, print-only)")
    ap.add_argument("--measure-scale", type=int, default=4, metavar="N",
                    help="with --measure: divide B0 spatial dims by N "
                         "(floored at the kernel size) so interpret-mode "
                         "timing stays affordable (default 4)")
    ap.add_argument("--measure-iters", type=int, default=3, metavar="N",
                    help="with --measure: timed iterations per point after "
                         "one warmup (default 3)")
    ap.add_argument("--no-persist", action="store_true",
                    help="with --measure: do NOT record stopwatch winners "
                         "in the schedule cache's measured tier")
    args = ap.parse_args()
    families = [t.strip() for t in args.family.lower().split(",")]
    for fam in families:
        if fam not in FAMILY_CHOICES:
            raise SystemExit(f"--family wants a comma list of "
                             f"{FAMILY_CHOICES}, got {fam!r}")
    if args.family != "b0" and not args.fused:
        raise SystemExit("--family requires --fused")
    if args.mesh is not None and not args.fused:
        raise SystemExit("--mesh requires --fused")
    if args.residency != "auto" and not args.fused:
        raise SystemExit("--residency requires --fused")
    if args.collective != "auto" and not args.fused:
        raise SystemExit("--collective requires --fused")
    if args.collective != "auto" \
            and (args.mesh is None or _parse_mesh(args.mesh)[1] <= 1):
        # without a model-sharded mesh the collective axis is degenerate
        # and a pin would be silently normalized to the ring — reject
        # instead of mislabeling the report
        raise SystemExit("--collective requires --mesh DxM with M > 1")
    if args.network and not args.fused:
        raise SystemExit("--network requires --fused")
    if args.pipeline and not args.network:
        raise SystemExit("--pipeline requires --network")
    if args.bench_out is not None and not args.measure:
        raise SystemExit("--bench-out requires --measure")
    measured_records = None
    if args.measure:
        if args.measure_scale < 1 or args.measure_iters < 1:
            raise SystemExit("--measure-scale/--measure-iters must be >= 1")
        measured_records = measure_b0(
            scale=args.measure_scale, iters=args.measure_iters,
            persist=not args.no_persist, bench_out=args.bench_out)
        if not args.fused:
            return
        print()
    if args.fused:
        mesh_shape = _parse_mesh(args.mesh) if args.mesh else (1, 1)
        collective = _parse_collective(args.collective)
        ok = True
        for res in _parse_residencies(args.residency):
            if "b0" in families:
                # the separable-family sweep rides with the default chain
                # only (it is family-independent of --family's choices)
                ok &= fused_traffic_report(mesh_shape, res)
                print()
            for fam in families:
                chain = family_chain(fam)
                if collective is None and mesh_shape[1] > 1:
                    ok &= mbconv_collective_sweep(mesh_shape, res, fam,
                                                  chain)
                else:
                    r_ok, _totals = mbconv_traffic_report(
                        mesh_shape, res, collective, fam, chain)
                    ok &= r_ok
                print()
        if args.network:
            for fam in families:
                ok &= network_report(mesh_shape, fam)
                print()
        if args.pipeline:
            for fam in families:
                ok &= pipeline_report(mesh_shape,
                                      records=measured_records, family=fam)
                print()
        for name, us, derived in mbconv_walltime_row():
            print(f"{name},{us:.1f},{derived}")
        sys.exit(0 if ok else 1)
    for name, us, derived in rows():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()

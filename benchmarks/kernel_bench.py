"""ConvDK kernel micro-benchmarks (CPU interpret-mode wall times; correctness
+ harness shape — real perf is measured via the dry-run roofline on TPU).

Emits ``name,us_per_call,derived`` CSV rows like benchmarks/run.py expects.

``--fused`` additionally prints the fused-vs-staged-vs-XLA separable-block
comparison: per-layer modeled HBM traffic for every MobileNet-V2 separable
block (autotuned schedules) plus interpret-mode wall times on one block.
Exits nonzero if any layer's fused traffic is not strictly below staged.
"""

from __future__ import annotations

import argparse
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.autotune import get_fused_schedule
from repro.core.workloads import MOBILENET_V2_SEPARABLE
from repro.kernels import (
    causal_conv1d_ref, convdk_causal_conv1d, convdk_depthwise2d,
    convdk_fused_separable, convdk_separable_staged, depthwise2d_ref,
    separable_ref,
)


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def rows():
    rng = np.random.default_rng(0)
    out = []

    # depthwise 2D: a MobileNet-ish layer
    x = jnp.asarray(rng.normal(size=(1, 28, 28, 128)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(3, 3, 128)), jnp.float32)
    us_k = _time(lambda: convdk_depthwise2d(x, w, interpret=True))
    us_r = _time(lambda: depthwise2d_ref(x, w))
    err = float(jnp.abs(convdk_depthwise2d(x, w, interpret=True)
                        - depthwise2d_ref(x, w)).max())
    out.append(("convdk_dw2d_28x28x128_interp", us_k, f"maxerr={err:.1e}"))
    out.append(("lax_dw2d_28x28x128_ref", us_r, ""))

    # fused separable block: same layer + 1x1 projection to 64 channels
    wp = jnp.asarray(rng.normal(size=(128, 64)), jnp.float32)
    us_f = _time(lambda: convdk_fused_separable(x, w, wp, interpret=True))
    us_s = _time(lambda: convdk_separable_staged(x, w, wp, interpret=True))
    us_x = _time(lambda: separable_ref(x, w, wp))
    err = float(jnp.abs(convdk_fused_separable(x, w, wp, interpret=True)
                        - separable_ref(x, w, wp)).max())
    out.append(("convdk_fused_sep_28x28x128to64_interp", us_f,
                f"maxerr={err:.1e}"))
    out.append(("convdk_staged_sep_28x28x128to64_interp", us_s, ""))
    out.append(("xla_sep_28x28x128to64_ref", us_x, ""))

    # causal conv1d: the Mamba-2 stem shape (per-device slice)
    xs = jnp.asarray(rng.normal(size=(2, 1024, 256)), jnp.float32)
    ws = jnp.asarray(rng.normal(size=(4, 256)), jnp.float32)
    us_k = _time(lambda: convdk_causal_conv1d(xs, ws, interpret=True))
    us_r = _time(lambda: causal_conv1d_ref(xs, ws))
    err = float(jnp.abs(convdk_causal_conv1d(xs, ws, interpret=True)
                        - causal_conv1d_ref(xs, ws)).max())
    out.append(("convdk_conv1d_1024x256_interp", us_k, f"maxerr={err:.1e}"))
    out.append(("lax_conv1d_1024x256_ref", us_r, ""))
    return out


def fused_traffic_report() -> bool:
    """Modeled HBM traffic, fused vs staged, every MobileNet-V2 separable
    block (batch 1, f32).  Returns True iff fused < staged for ALL layers."""
    print("layer,c_in,hw,s,c_out,tile_h,fused_bytes,staged_bytes,saving_pct")
    ok = True
    for i, (layer, c_out) in enumerate(MOBILENET_V2_SEPARABLE):
        sch = get_fused_schedule(1, layer.h, layer.w, layer.c, c_out,
                                 layer.k, layer.s)
        f, s = sch.traffic.total_bytes, sch.staged_traffic.total_bytes
        ok &= f < s
        print(f"mbv2_dw{i},{layer.c},{layer.h},{layer.s},{c_out},"
              f"{sch.tile_h},{f},{s},{100 * sch.modeled_saving:.1f}")
    print(f"# fused strictly below staged on all layers: {ok}")
    return ok


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--fused", action="store_true",
                    help="print the fused-vs-staged MobileNet-V2 HBM "
                         "traffic comparison (exit 1 if fused loses a layer)")
    args = ap.parse_args()
    if args.fused:
        sys.exit(0 if fused_traffic_report() else 1)
    for name, us, derived in rows():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()

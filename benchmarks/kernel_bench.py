"""ConvDK kernel micro-benchmarks (CPU interpret-mode wall times; correctness
+ harness shape — real perf is measured via the dry-run roofline on TPU).

Emits ``name,us_per_call,derived`` CSV rows like benchmarks/run.py expects.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import (
    causal_conv1d_ref, convdk_causal_conv1d, convdk_depthwise2d,
    depthwise2d_ref,
)


def _time(fn, *args, iters=5):
    fn(*args)[0].block_until_ready() if isinstance(fn(*args), tuple) else \
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6


def rows():
    rng = np.random.default_rng(0)
    out = []

    # depthwise 2D: a MobileNet-ish layer
    x = jnp.asarray(rng.normal(size=(1, 28, 28, 128)), jnp.float32)
    w = jnp.asarray(rng.normal(size=(3, 3, 128)), jnp.float32)
    us_k = _time(lambda: convdk_depthwise2d(x, w, interpret=True))
    us_r = _time(lambda: depthwise2d_ref(x, w))
    err = float(jnp.abs(convdk_depthwise2d(x, w, interpret=True)
                        - depthwise2d_ref(x, w)).max())
    out.append(("convdk_dw2d_28x28x128_interp", us_k, f"maxerr={err:.1e}"))
    out.append(("lax_dw2d_28x28x128_ref", us_r, ""))

    # causal conv1d: the Mamba-2 stem shape (per-device slice)
    xs = jnp.asarray(rng.normal(size=(2, 1024, 256)), jnp.float32)
    ws = jnp.asarray(rng.normal(size=(4, 256)), jnp.float32)
    us_k = _time(lambda: convdk_causal_conv1d(xs, ws, interpret=True))
    us_r = _time(lambda: causal_conv1d_ref(xs, ws))
    err = float(jnp.abs(convdk_causal_conv1d(xs, ws, interpret=True)
                        - causal_conv1d_ref(xs, ws)).max())
    out.append(("convdk_conv1d_1024x256_interp", us_k, f"maxerr={err:.1e}"))
    out.append(("lax_conv1d_1024x256_ref", us_r, ""))
    return out


def main():
    for name, us, derived in rows():
        print(f"{name},{us:.1f},{derived}")


if __name__ == "__main__":
    main()

"""Train a MobileNetV1-style depthwise-separable CNN whose DWConv layers run
the ConvDK Pallas kernel (interpret mode on CPU) — the paper's own model
family, end to end trainable through the paper's dataflow.

    PYTHONPATH=src python examples/train_mobilenet_cim.py [--steps 60]
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import convdk_depthwise2d
from repro.models.param import P, materialize


def model_def(c0=16, n_blocks=3, n_classes=10):
    p = {"stem": P((3, 3, 3, c0), (None, None, None, None))}
    c = c0
    for i in range(n_blocks):
        p[f"dw{i}"] = P((3, 3, c), (None, None, None))
        p[f"pw{i}"] = P((c, c * 2), (None, None), scale=2.0)
        c *= 2
    p["head"] = P((c, n_classes), (None, None))
    return p


def forward(params, x):
    # stem: ordinary 3x3 conv stride 2
    x = jax.lax.conv_general_dilated(
        x, params["stem"], (2, 2), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    x = jax.nn.relu(x)
    i = 0
    while f"dw{i}" in params:
        # depthwise stage: the ConvDK kernel (stride 2 shrinks the map)
        x = convdk_depthwise2d(x, params[f"dw{i}"], stride=2,
                               padding="SAME", interpret=True)
        x = jax.nn.relu(x)
        # pointwise stage: 1x1 conv = matmul over channels
        x = jax.nn.relu(x @ params[f"pw{i}"])
        i += 1
    x = x.mean(axis=(1, 2))                      # global average pool
    return x @ params["head"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    args = ap.parse_args()

    params = materialize(model_def(), jax.random.key(0))
    rng = np.random.default_rng(0)

    def batch(step):
        r = np.random.default_rng((0, step))
        y = r.integers(0, 10, (32,))
        x = r.normal(size=(32, 32, 32, 3)).astype(np.float32) * 0.1
        # class-dependent blob so the task is learnable
        for b, cls in enumerate(y):
            x[b, cls:cls + 8, cls:cls + 8, :] += 1.0
        return jnp.asarray(x), jnp.asarray(y)

    @jax.jit
    def step(params, x, y):
        def loss_fn(p):
            logits = forward(p, x)
            logz = jax.nn.logsumexp(logits, -1)
            gold = jnp.take_along_axis(logits, y[:, None], -1)[:, 0]
            return (logz - gold).mean()

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params = jax.tree.map(lambda p, g: p - 0.5 * g, params, grads)
        return params, loss

    losses = []
    for i in range(args.steps):
        x, y = batch(i)
        params, loss = step(params, x, y)
        losses.append(float(loss))
        if (i + 1) % 10 == 0:
            print(f"step {i+1}: loss {losses[-1]:.3f}")
    print(f"\nloss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({'DESCENDED' if losses[-1] < losses[0] * 0.7 else 'check'}) — "
          f"DWConv stages ran the ConvDK Pallas kernel")


if __name__ == "__main__":
    main()

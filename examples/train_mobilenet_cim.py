"""Train a MobileNetV1-style depthwise-separable CNN whose separable blocks
run the FUSED ConvDK Pallas kernel (DW taps + mid-block ReLU + 1x1 PW in one
VMEM residency; interpret mode on CPU) — the paper's own model family, end
to end trainable through the paper's dataflow with one HBM read per block.

    PYTHONPATH=src python examples/train_mobilenet_cim.py [--steps 60]
    PYTHONPATH=src python examples/train_mobilenet_cim.py --staged  # A/B

``--staged`` flips the routing flag in ``repro.configs.base`` back to the
two-kernel pipeline (stage_row_strips -> DW kernel -> HBM -> PW matmul) so
the two executables can be compared on the same run.
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import kernel_config, set_kernel_config
from repro.models.common import separable_block, separable_def
from repro.models.param import P, materialize


def model_def(c0=16, n_blocks=3, n_classes=10):
    p = {"stem": P((3, 3, 3, c0), (None, None, None, None))}
    c = c0
    for i in range(n_blocks):
        p[f"sep{i}"] = separable_def(c, c * 2, k=3)
        c *= 2
    p["head"] = P((c, n_classes), (None, None))
    return p


def forward(params, x):
    # stem: ordinary 3x3 conv stride 2
    x = jax.lax.conv_general_dilated(
        x, params["stem"], (2, 2), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    x = jax.nn.relu(x)
    i = 0
    while f"sep{i}" in params:
        # DW + ReLU + PW + ReLU: ONE fused ConvDK kernel per block (the
        # staged two-kernel path when --staged flips the config flag)
        x = separable_block(params[f"sep{i}"], x, stride=2,
                            dw_act="relu", act="relu")
        i += 1
    x = x.mean(axis=(1, 2))                      # global average pool
    return x @ params["head"]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--staged", action="store_true",
                    help="route separable blocks through the staged "
                         "two-kernel pipeline instead of the fused kernel")
    args = ap.parse_args()
    set_kernel_config(fused_separable=not args.staged, interpret=True)

    params = materialize(model_def(), jax.random.key(0))

    def batch(step):
        r = np.random.default_rng((0, step))
        y = r.integers(0, 10, (32,))
        x = r.normal(size=(32, 32, 32, 3)).astype(np.float32) * 0.1
        # class-dependent blob so the task is learnable
        for b, cls in enumerate(y):
            x[b, cls:cls + 8, cls:cls + 8, :] += 1.0
        return jnp.asarray(x), jnp.asarray(y)

    @jax.jit
    def step(params, x, y):
        def loss_fn(p):
            logits = forward(p, x)
            logz = jax.nn.logsumexp(logits, -1)
            gold = jnp.take_along_axis(logits, y[:, None], -1)[:, 0]
            return (logz - gold).mean()

        loss, grads = jax.value_and_grad(loss_fn)(params)
        params = jax.tree.map(lambda p, g: p - 0.5 * g, params, grads)
        return params, loss

    losses = []
    for i in range(args.steps):
        x, y = batch(i)
        params, loss = step(params, x, y)
        losses.append(float(loss))
        if (i + 1) % 10 == 0:
            print(f"step {i+1}: loss {losses[-1]:.3f}")
    path = "fused" if kernel_config().fused_separable else "staged"
    print(f"\nloss {losses[0]:.3f} -> {losses[-1]:.3f} "
          f"({'DESCENDED' if losses[-1] < losses[0] * 0.7 else 'check'}) — "
          f"separable blocks ran the {path} ConvDK Pallas pipeline")


if __name__ == "__main__":
    main()

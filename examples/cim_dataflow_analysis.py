"""Reproduce the paper's full evaluation (Figs. 7-8) and print the
comparison against every reported band.

    PYTHONPATH=src python -m examples.cim_dataflow_analysis

Runnable as a module (like the other entry points) from the repo root; a
direct ``python examples/cim_dataflow_analysis.py`` also works — the repo
root is resolved from this file, not from the current directory.
"""

import sys
from pathlib import Path

if __package__ in (None, ""):                 # direct-script invocation
    sys.path.insert(0, str(Path(__file__).resolve().parent.parent))

from benchmarks.cim_tables import run_all  # noqa: E402

from repro.core.workloads import PAPER_BANDS  # noqa: E402


def main():
    results = run_all()

    print("\n== reproduction vs paper bands ==")
    ws = [v["ws"] for v in results["fig7c"].values()]
    lo, hi = PAPER_BANDS["buffer_traffic_reduction_ws"]
    print(f"buffer traffic reduction (WS): ours {min(ws):.1f}..{max(ws):.1f} "
          f"| paper {lo}..{hi}")
    tot = [v["ws_total"] for v in results["fig7d"].values()]
    lo, hi = PAPER_BANDS["energy_reduction_ws"]
    print(f"traffic energy reduction (WS): ours {min(tot):.1f}..{max(tot):.1f} "
          f"| paper {lo}..{hi}")
    lat = [v["ws"] for v in results["fig7e"].values()]
    lo, hi = PAPER_BANDS["latency_reduction_ws"]
    print(f"latency reduction (WS):        ours {min(lat):.1f}..{max(lat):.1f} "
          f"| paper {lo}..{hi}")
    f8 = [v["ws"] for v in results["fig8"].values()]
    lo, hi = PAPER_BANDS["buffer_latency_reduction_ws"]
    print(f"buffer-latency reduction (WS): ours {min(f8):.1f}..{max(f8):.1f} "
          f"| paper {lo}..{hi}")


if __name__ == "__main__":
    main()

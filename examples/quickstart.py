"""Quickstart: the paper's ConvDK dataflow in five minutes.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax.numpy as jnp
import numpy as np

from repro.core.schedule import make_schedule, is_exact_cover
from repro.core.convdk import dwconv2d_convdk, dwconv2d_oracle
from repro.core.tiling import DWLayer, plan_layer
from repro.core.perfmodel import cost_ws_base, cost_ws_convdk, reduction
from repro.kernels import convdk_depthwise2d, depthwise2d_ref

# 1. The number theory: the paper's worked example (k=3, s=2, N=30).
sched = make_schedule(k=3, s=2, N=30)
print(f"ConvDK schedule k=3 s=2 N=30: l={sched.l} shift cycles, "
      f"m1={sched.m1}, n1={sched.n1}")
print(f"  cycle a=0 computes outputs m = {sched.cycles[0].ms[:5]}...")
print(f"  Theorem 2 exact cover: {is_exact_cover(sched)}")

# 2. ConvDK computes the SAME depthwise conv, with one strip load per row.
rng = np.random.default_rng(0)
x = jnp.asarray(rng.normal(size=(8, 24, 24)), jnp.float32)       # (C, H, W)
k = jnp.asarray(rng.normal(size=(8, 3, 3)), jnp.float32)
out_dk = dwconv2d_convdk(x, k, stride=1, padding="SAME")
out_ref = dwconv2d_oracle(x, k, stride=1, padding="SAME")
print(f"\nConvDK == lax depthwise conv: "
      f"{bool(jnp.allclose(out_dk, out_ref, atol=1e-4))}")

# 3. The BIG/LITTLE scheduler picks the macro plan (Fig. 5's example).
plan = plan_layer(DWLayer(c=128, h=24, w=24, k=3, s=1))
print(f"\n128x24x24 DWConv -> {plan.mode} scheduler, N_ch={plan.n_ch}, "
      f"TM utilization {plan.tm_utilization:.0%}")

# 4. Buffer traffic: the paper's headline.
layer = DWLayer(c=512, h=14, w=14, k=3, s=1)
base, ours = cost_ws_base(layer), cost_ws_convdk(layer)
print(f"512x14x14: buffer traffic {base.buffer_words} -> {ours.buffer_words} "
      f"words ({reduction(base.buffer_words, ours.buffer_words):.1f}% less)")

# 5. The TPU kernel (Pallas, interpret mode on CPU) — same dataflow idea:
#    strip resident in VMEM, k shifted re-reads, channels on the lanes.
xb = jnp.asarray(rng.normal(size=(2, 14, 14, 32)), jnp.float32)   # NHWC
kb = jnp.asarray(rng.normal(size=(3, 3, 32)), jnp.float32)
got = convdk_depthwise2d(xb, kb, stride=1, padding="SAME", interpret=True)
want = depthwise2d_ref(xb, kb, stride=1, padding="SAME")
print(f"\nPallas ConvDK kernel == oracle: "
      f"{bool(jnp.allclose(got, want, atol=1e-4))}")

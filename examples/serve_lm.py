"""End-to-end serving driver (the paper's kind: an inference accelerator):
batched requests through the BIG/LITTLE admission scheduler and the
per-family cache engine.

    PYTHONPATH=src python examples/serve_lm.py [--arch mamba2-2.7b]
"""

import argparse
import time

import jax
import numpy as np

from repro.configs import get_arch
from repro.models.model import model_def
from repro.models.param import materialize
from repro.serve.engine import Engine, ServeConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-2.7b")
    ap.add_argument("--new-tokens", type=int, default=8)
    args = ap.parse_args()

    cfg = get_arch(args.arch).smoke
    params = materialize(model_def(cfg), jax.random.key(0))
    engine = Engine(cfg, params, ServeConfig(
        max_new_tokens=args.new_tokens, little_threshold=16))

    # a mixed request stream: many short prompts + a few long ones
    rng = np.random.default_rng(0)
    requests = [rng.integers(0, cfg.vocab, rng.choice([6, 8, 40]))
                for _ in range(12)]
    batches = engine.schedule(requests)
    print(f"{len(requests)} requests -> {len(batches)} launches "
          f"(BIG/LITTLE admission): {[len(b) for b in batches]}")

    t0 = time.time()
    # generate_many consumes schedule() itself: LITTLE packs left-pad to
    # shared length buckets, BIG prompts run alone, outputs come back in
    # request order
    outs = engine.generate_many(requests)
    done = sum(o.size for o in outs)
    dt = time.time() - t0
    print(f"served {done} tokens in {dt:.2f}s ({done/dt:.1f} tok/s, "
          f"family={cfg.family} cache)")


if __name__ == "__main__":
    main()

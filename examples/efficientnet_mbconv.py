"""EfficientNet-B0 end to end through the two-pass fused MBConv pipeline.

Prints the per-layer two-pass schedule table (tile_h + retain/recompute
choice and the modeled HBM traffic vs the staged DW->HBM->SE->PW baseline)
for the full-size B0, then runs a width-scaled B0 forward + one training
step with every MBConv block executing the fused ConvDK kernels (interpret
mode on CPU).

    PYTHONPATH=src python -m examples.efficientnet_mbconv [--hw 32]
    PYTHONPATH=src python -m examples.efficientnet_mbconv --staged   # A/B
"""

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import set_kernel_config
from repro.configs.efficientnet_b0 import efficientnet_b0_smoke
from repro.core.autotune import get_mbconv_schedule
from repro.core.workloads import EFFICIENTNET_B0_MBCONV
from repro.models.mbconv import (
    effnet_block_specs,
    efficientnet_b0_apply,
    efficientnet_b0_def,
)
from repro.models.param import count_params, materialize


def schedule_table():
    print("== EfficientNet-B0 two-pass fused MBConv schedules (batch 1) ==")
    print(f"{'layer':<12}{'c_in':>5}{'c_mid':>6}{'c_out':>6}{'hw':>4}"
          f"{'k':>3}{'s':>3}{'tile_h':>7}{'mode':>11}{'saving':>8}")
    total_f = total_s = 0
    for i, (ci, co, e, k, s, hw) in enumerate(EFFICIENTNET_B0_MBCONV):
        sch = get_mbconv_schedule(1, hw, hw, ci, ci * e, co, k, s)
        total_f += sch.traffic.total_bytes
        total_s += sch.staged_traffic.total_bytes
        print(f"{'b0_mbconv' + str(i):<12}{ci:>5}{ci * e:>6}{co:>6}{hw:>4}"
              f"{k:>3}{s:>3}{sch.tile_h:>7}{sch.mode:>11}"
              f"{100 * sch.modeled_saving:>7.1f}%")
    print(f"network total: fused {total_f / 1e6:.1f} MB vs staged "
          f"{total_s / 1e6:.1f} MB "
          f"({100 * (1 - total_f / total_s):.1f}% HBM traffic avoided)\n")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--hw", type=int, default=32,
                    help="input resolution for the smoke forward/backward")
    ap.add_argument("--staged", action="store_true",
                    help="route MBConv blocks through the staged "
                         "DW->HBM->SE->PW baseline instead of the two-pass "
                         "fused pipeline")
    args = ap.parse_args()
    set_kernel_config(fused_mbconv=not args.staged, interpret=True)

    schedule_table()

    cfg = efficientnet_b0_smoke()
    params = materialize(efficientnet_b0_def(cfg), jax.random.key(0))
    specs = effnet_block_specs(cfg)
    print(f"smoke B0: width x{cfg.width_mult}, {len(specs)} MBConv blocks, "
          f"{count_params(efficientnet_b0_def(cfg)):,} params, "
          f"input {args.hw}x{args.hw}")

    rng = np.random.default_rng(0)
    x = jnp.asarray(rng.normal(size=(2, args.hw, args.hw, 3)), jnp.float32)
    y = jnp.asarray(rng.integers(0, cfg.num_classes, (2,)))

    logits = efficientnet_b0_apply(params, x, cfg)
    print(f"forward: logits {logits.shape}, "
          f"finite={bool(jnp.isfinite(logits).all())}")

    def loss_fn(p):
        lg = efficientnet_b0_apply(p, x, cfg)
        logz = jax.nn.logsumexp(lg, -1)
        gold = jnp.take_along_axis(lg, y[:, None], -1)[:, 0]
        return (logz - gold).mean()

    loss, grads = jax.value_and_grad(loss_fn)(params)
    gnorm = jnp.sqrt(sum(jnp.sum(g ** 2) for g in jax.tree.leaves(grads)))
    path = "staged" if args.staged else "two-pass fused"
    print(f"backward: loss {float(loss):.3f}, grad norm {float(gnorm):.3f} — "
          f"every MBConv block ran the {path} ConvDK pipeline")


if __name__ == "__main__":
    main()

"""End-to-end training driver: train a small LM for a few hundred steps on
the synthetic learnable stream, with periodic checkpointing and restart.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""

import argparse
import tempfile

from repro.configs import get_arch
from repro.data.pipeline import DataConfig
from repro.launch.mesh import make_local_mesh
from repro.launch.train import Trainer
from repro.train.optim import OptimConfig
from repro.train.step import TrainConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--arch", default="gemma-2b")
    args = ap.parse_args()

    cfg = get_arch(args.arch).smoke
    dcfg = DataConfig(vocab=cfg.vocab, seq_len=64, global_batch=16,
                      family=cfg.family, d_model=cfg.d_model,
                      n_img_tokens=cfg.n_img_tokens)
    tcfg = TrainConfig(optim=OptimConfig(
        peak_lr=3e-3, warmup_steps=20, decay_steps=args.steps))

    with tempfile.TemporaryDirectory() as ckpt_dir:
        tr = Trainer(cfg, tcfg, dcfg, ckpt_dir=ckpt_dir,
                     mesh=make_local_mesh())
        tr.install_signal_handler()
        losses = tr.run(args.steps, ckpt_every=100, log_every=25)
        print(f"\nloss {losses[0]:.3f} -> {losses[-1]:.3f} over "
              f"{len(losses)} steps "
              f"({'DESCENDED' if losses[-1] < losses[0] else 'FLAT'})")


if __name__ == "__main__":
    main()
